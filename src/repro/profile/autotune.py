"""Autotuner: sweep the kernel knobs, persist the winner per backend.

Three knobs are tuned, all previously raw env vars:

- ``pack``: packed megakernel vs per-leaf Iter-Fisher dispatch
  (``REPRO_PACK``). ``BENCH_hotpath.json`` showed the packed kernel ~7×
  *slower* on CPU interpret — exactly the case a measured default fixes.
- ``pack_block``: the ``PackSpec`` grid tile (``REPRO_PACK_BLOCK``).
- ``segment_buckets``: the ``EngineCache`` segment-length bucket ladder
  (``REPRO_SEGMENT_BUCKETS``), traded from measured (compile_s,
  per_round_s).

The *choices* are pure functions of the measurements (same measurements →
same choice, tested), so records are reproducible and diffable. Winners
are stored under ``kind="autotune"`` keyed by the backend fingerprint;
``tuned_defaults()`` is the read side consumed by ``kernels.ops`` and
``core.ferret.EngineCache``. Precedence everywhere is

    explicit env var  >  tuned record  >  built-in heuristic/default
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.profile.store import ProfileStore, backend_fingerprint, default_store

TUNE_KIND = "autotune"

# Pack-block candidates: ALIGN-multiples spanning "one tile per launch"
# to "few big tiles" (8·128 = 1024 is the fp32 VPU tile).
DEFAULT_BLOCK_CANDIDATES = (1024, 4096, 16384)

# Nominal (segment_rounds, weight) workload for the bucket cost model:
# pipelined default 32, elastic segments around it, serve-style short
# slices, and the occasional long materialized run.
DEFAULT_SEGMENT_DIST: Tuple[Tuple[int, int], ...] = (
    (8, 2), (16, 2), (24, 1), (32, 6), (48, 2), (64, 3),
    (96, 1), (128, 2), (192, 1), (256, 1), (512, 1),
)


@dataclasses.dataclass(frozen=True)
class TunedDefaults:
    """Measured default knob values for one backend (None = no opinion)."""

    pack: Optional[bool] = None
    pack_block: Optional[int] = None
    segment_buckets: Optional[Tuple[int, ...]] = None
    source: str = "none"  # "none" | "store"


# ---------------------------------------------------------------------------
# Pure choice functions (measurements in, knob values out — deterministic)
# ---------------------------------------------------------------------------


def choose_pack(measurements: Dict[str, Dict]) -> Tuple[bool, Optional[int]]:
    """(pack?, block) from ``measure_kernel_variants`` output.

    The winner is the lowest mean latency; ties break toward ``per_leaf``
    (no packing machinery) and then the smaller block, so equal
    measurements can never flap the choice between runs.
    """
    if "per_leaf" not in measurements:
        raise ValueError("measurements must include the per_leaf baseline")

    def rank(item):
        name, m = item
        is_packed = name != "per_leaf"
        return (float(m["mean_s"]), is_packed, int(m.get("block", 0)))

    name, m = min(measurements.items(), key=rank)
    if name == "per_leaf":
        return False, None
    return True, int(m["block"])


def _bucket_len(buckets: Sequence[int], n: int) -> int:
    for b in buckets:
        if n <= b:
            return b
    top = buckets[-1]
    return ((n + top - 1) // top) * top


def bucket_cost(
    buckets: Sequence[int],
    compile_s: float,
    per_round_s: float,
    dist: Iterable[Tuple[int, int]] = DEFAULT_SEGMENT_DIST,
) -> float:
    """Expected cost of a bucket ladder over a segment-length workload:
    one compile per distinct bucket touched + one step per padded round."""
    buckets = sorted(buckets)
    used = set()
    padded = 0.0
    for n, weight in dist:
        b = _bucket_len(buckets, n)
        used.add(b)
        padded += (b - n) * weight
    return len(used) * compile_s + padded * per_round_s


def candidate_bucket_ladders() -> Tuple[Tuple[int, ...], ...]:
    from repro.core.ferret import DEFAULT_SEGMENT_BUCKETS

    full = tuple(DEFAULT_SEGMENT_BUCKETS)
    sparse = tuple(b for i, b in enumerate(full) if i % 2 == 0)  # ratio ~4
    dense = tuple(sorted(set(full) | {b + b // 2 for b in full[:-1]}))
    return (full, sparse, dense)


def choose_buckets(
    compile_s: float,
    per_round_s: float,
    candidates: Optional[Sequence[Sequence[int]]] = None,
    dist: Iterable[Tuple[int, int]] = DEFAULT_SEGMENT_DIST,
) -> Tuple[int, ...]:
    """The candidate ladder with the lowest expected cost (ties break
    toward fewer buckets, then lexicographically — deterministic)."""
    cands = [tuple(sorted(c)) for c in (candidates or candidate_bucket_ladders())]
    dist = tuple(dist)
    return min(cands, key=lambda c: (bucket_cost(c, compile_s, per_round_s, dist), len(c), c))


# ---------------------------------------------------------------------------
# Measure → choose → persist
# ---------------------------------------------------------------------------


def _tiny_tune_config():
    """Benchmark-scale model for the bucket cost measurement."""
    from repro.models.config import ModelConfig

    return ModelConfig(
        name="tune-lm", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32,
        compute_dtype="float32",
    )


def autotune(
    store: Optional[ProfileStore] = None,
    *,
    blocks: Sequence[int] = DEFAULT_BLOCK_CANDIDATES,
    tune_buckets: bool = False,
    cfg=None,
    batch: int = 2,
    seq: int = 16,
    warmup: int = 2,
    repeats: int = 5,
    tau: int = 4,
) -> TunedDefaults:
    """Sweep the knobs on the live backend and record the winners.

    ``tune_buckets`` additionally measures scan compile/per-round cost for
    the bucket ladder choice — it compiles a real segment, so it is off by
    default (CLI ``launch/profile.py tune --buckets`` turns it on).
    """
    from repro.profile import harness

    store = store or default_store()
    fp = backend_fingerprint()
    measurements = harness.measure_kernel_variants(
        tau=tau, blocks=blocks, warmup=warmup, repeats=repeats
    )
    pack, pack_block = choose_pack(measurements)
    payload: Dict = {
        "pack": pack,
        "pack_block": pack_block,
        "kernel_measurements": measurements,
    }
    if tune_buckets:
        compile_s, per_round_s = harness.measure_scan_segment(
            cfg or _tiny_tune_config(), batch=batch, seq=seq
        )
        buckets = choose_buckets(compile_s, per_round_s)
        payload["segment_buckets"] = list(buckets)
        payload["bucket_inputs"] = {"compile_s": compile_s, "per_round_s": per_round_s}
    store.put(TUNE_KIND, {"backend": fp}, payload)
    clear_tuned_cache()
    return TunedDefaults(
        pack=pack,
        pack_block=pack_block,
        segment_buckets=tuple(payload["segment_buckets"]) if tune_buckets else None,
        source="store",
    )


# ---------------------------------------------------------------------------
# Read side: cached tuned defaults for dispatch call sites
# ---------------------------------------------------------------------------

_TUNED_CACHE: Dict[Tuple[str, str], TunedDefaults] = {}
_TUNED_LOCK = threading.Lock()
_NONE = TunedDefaults()


def tuned_defaults(store: Optional[ProfileStore] = None) -> TunedDefaults:
    """The persisted tuned defaults for the current backend (cheap:
    cached per (store root, backend fingerprint); ``TunedDefaults()``
    with all-None fields when nothing was tuned or anything fails)."""
    try:
        store = store or default_store()
        fp = backend_fingerprint()
    except Exception:
        return _NONE
    cache_key = (store.root, fp)
    with _TUNED_LOCK:
        hit = _TUNED_CACHE.get(cache_key)
        if hit is not None:
            return hit
    try:
        payload = store.get(TUNE_KIND, {"backend": fp})
    except Exception:
        payload = None
    if payload is None:
        tuned = _NONE
    else:
        raw_buckets = payload.get("segment_buckets")
        tuned = TunedDefaults(
            pack=payload.get("pack"),
            pack_block=payload.get("pack_block"),
            segment_buckets=tuple(int(b) for b in raw_buckets) if raw_buckets else None,
            source="store",
        )
    with _TUNED_LOCK:
        _TUNED_CACHE[cache_key] = tuned
    return tuned


def clear_tuned_cache() -> None:
    """Invalidate the in-process tuned-defaults cache (tests, re-tunes)."""
    with _TUNED_LOCK:
        _TUNED_CACHE.clear()
