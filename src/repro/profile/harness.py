"""Measurement harness: timed on-device executions for profiles and tuning.

This is the one place in the repo that times real executions (paper
appendix Alg. 3, ``profile(θ)``). Every timing goes through ``time_jit``:
explicit ``lower().compile()`` so compile time is measured separately,
``warmup`` executions to flush first-touch costs, ``repeats`` timed runs
with ``block_until_ready``, and the compiled executable's
``cost_analysis`` (FLOPs / bytes accessed) recorded as a cross-check
against the analytic roofline.

Consumers:
- ``measure_model_profile`` → a measured ``ModelProfile`` for the planner
  (``core.profiler.measured_profile`` delegates here — one code path).
- ``measure_kernel_variants`` → packed-vs-per-leaf Iter-Fisher latency per
  candidate pack block, consumed by ``repro.profile.autotune``.
- ``measure_scan_segment`` → scan compile + per-round step time, feeding
  the segment-bucket cost model.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.config import ModelConfig

DEFAULT_WARMUP = 2
DEFAULT_REPEATS = 5


@dataclasses.dataclass(frozen=True)
class Timing:
    """One timed compiled executable."""

    mean_s: float
    best_s: float
    compile_s: float
    repeats: int
    flops: float  # XLA cost_analysis estimate (0.0 if unavailable)
    bytes_accessed: float

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def time_jit(
    fn: Callable,
    *args,
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
) -> Timing:
    """Compile ``fn(*args)`` and time it: warmup + repeated blocking runs."""
    t0 = time.perf_counter()
    compiled = jax.jit(fn).lower(*args).compile()
    compile_s = time.perf_counter() - t0
    try:
        cost = compat.cost_analysis_dict(compiled)
    except Exception:
        cost = {}
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(compiled(*args))
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        times.append(time.perf_counter() - t0)
    return Timing(
        mean_s=sum(times) / len(times),
        best_s=min(times),
        compile_s=compile_s,
        repeats=len(times),
        flops=float(cost.get("flops", 0.0) or 0.0),
        bytes_accessed=float(cost.get("bytes accessed", 0.0) or 0.0),
    )


# ---------------------------------------------------------------------------
# Per-layer forward/backward blocks → measured ModelProfile
# ---------------------------------------------------------------------------


def measure_model_profile(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
    rng_seed: int = 0,
):
    """Wall-clock ``ModelProfile`` from timing one real block fwd/bwd.

    Byte sizes stay analytic (they are exact layout facts, not
    measurements); only the times are replaced by device wall-clock.
    """
    from repro.core import profiler as P
    from repro.models import transformer as T
    from repro.models.transformer import _block_train

    one = dataclasses.replace(cfg, num_layers=1)
    params = T.init_params(one, jax.random.PRNGKey(rng_seed))
    block = jax.tree.map(lambda a: a[0], params["blocks"])
    x = jnp.zeros((batch, seq, cfg.d_model), dtype=jnp.dtype(cfg.compute_dtype))
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, batch, seq))

    fwd = time_jit(
        lambda p, xx: _block_train(cfg, p, xx, jnp.int32(0), pos)[0],
        block, x, warmup=warmup, repeats=repeats,
    )
    bwd = time_jit(
        jax.grad(lambda p, xx: jnp.sum(_block_train(cfg, p, xx, jnp.int32(0), pos)[0] ** 2)),
        block, x, warmup=warmup, repeats=repeats,
    )

    w_b = P._block_w_bytes(cfg)
    a_b = P._block_a_bytes(cfg, batch, seq)
    a_int = P._block_a_internal_bytes(cfg, batch, seq)
    layers = [
        P.LayerProfile(fwd.mean_s, bwd.mean_s, w_b, a_b, a_int)
        for _ in range(cfg.num_layers)
    ]
    embed_bytes = cfg.vocab_size * cfg.d_model * 4 * (1 if cfg.tie_embeddings else 2)
    return P.ModelProfile(
        layers=layers, embed_bytes=embed_bytes, batch=batch, seq=seq,
        provenance="measured",
    ), {"fwd": fwd.to_dict(), "bwd": bwd.to_dict()}


# ---------------------------------------------------------------------------
# Iter-Fisher kernel variants (packed vs per-leaf, candidate pack blocks)
# ---------------------------------------------------------------------------


def default_tuning_tree(scale: int = 1) -> Dict:
    """A stage-params-shaped pytree: mixed 2D matmuls + ragged 1D vectors."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    d = 64 * scale
    return {
        "wq": jax.random.normal(ks[0], (d, d), jnp.float32),
        "w_ff1": jax.random.normal(ks[1], (d, 2 * d), jnp.float32),
        "w_ff2": jax.random.normal(ks[2], (2 * d, d), jnp.float32),
        "b1": jax.random.normal(ks[3], (2 * d,), jnp.float32),
        "scale": jax.random.normal(ks[4], (d,), jnp.float32),
        "b2": jax.random.normal(ks[5], (3,), jnp.float32),  # ragged: pad path
    }


def measure_kernel_variants(
    tree: Optional[Dict] = None,
    tau: int = 4,
    alpha: float = 0.9,
    blocks: Sequence[int] = (),
    warmup: int = DEFAULT_WARMUP,
    repeats: int = DEFAULT_REPEATS,
) -> Dict:
    """Time compensate+stats per dispatch variant on the current backend.

    Variants: ``per_leaf`` (the O(leaves) loop) and ``packed@<block>`` for
    each candidate pack block (``()`` → just the default block). Dispatch
    flags (Pallas vs jnp, interpret) follow the live ``ops`` heuristics so
    the measurement matches what a real run would execute.
    """
    from repro.kernels import ops, packing

    tree = tree if tree is not None else default_tuning_tree()
    lam = jnp.float32(0.01)
    deltas = jax.tree.map(
        lambda a: jnp.stack([a * (0.01 * (i + 1)) for i in range(tau)]), tree
    )
    delta1 = jax.tree.map(lambda a: a * 0.01, tree)
    zeros = jax.tree.map(jnp.zeros_like, tree)
    use_pallas = ops._use_pallas()
    interpret = ops._pallas_interpret()

    def per_leaf(g, d, d1, vr, va):
        comp = jax.tree.map(lambda gg, dd: ops.iter_fisher_compensate(gg, dd, lam), g, d)
        _, _, s1, s2 = ops.iter_fisher_stats_tree(g, d1, vr, va, alpha, packed=False)
        return comp, s1, s2

    out: Dict[str, Dict] = {
        "per_leaf": time_jit(
            per_leaf, tree, deltas, delta1, zeros, zeros,
            warmup=warmup, repeats=repeats,
        ).to_dict()
    }

    block_list: List[Optional[int]] = list(blocks) if blocks else [None]
    for block in block_list:
        def packed_fn(g, d, d1, vr, va, _block=block):
            comp = packing.compensate_tree(
                g, d, lam, use_pallas=use_pallas, interpret=interpret, block=_block
            )
            _, _, s1, s2 = packing.stats_tree(
                g, d1, vr, va, alpha,
                use_pallas=use_pallas, interpret=interpret, block=_block,
            )
            return comp, s1, s2

        label = f"packed@{block if block is not None else packing.BLOCK}"
        out[label] = time_jit(
            packed_fn, tree, deltas, delta1, zeros, zeros,
            warmup=warmup, repeats=repeats,
        ).to_dict()
        out[label]["block"] = block if block is not None else packing.BLOCK
    return out


# ---------------------------------------------------------------------------
# Segment-bucket cost inputs (compile time vs per-round step time)
# ---------------------------------------------------------------------------


def measure_scan_segment(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    rounds: int = 8,
    warmup: int = 1,
    repeats: int = 3,
    rng_seed: int = 0,
) -> Tuple[float, float]:
    """(compile_s, per_round_s) for a scanned block-train segment.

    A proxy for ``FerretEngine`` segment execution: one jitted
    ``lax.scan`` of the block fwd/bwd over ``rounds`` rounds. Bucketing
    trades these two numbers — each distinct bucket costs one compile;
    each padded round costs one per-round step.
    """
    from repro.models import transformer as T
    from repro.models.transformer import _block_train

    one = dataclasses.replace(cfg, num_layers=1)
    params = T.init_params(one, jax.random.PRNGKey(rng_seed))
    block = jax.tree.map(lambda a: a[0], params["blocks"])
    x = jnp.zeros((rounds, batch, seq, cfg.d_model), dtype=jnp.dtype(cfg.compute_dtype))
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, batch, seq))

    grad_fn = jax.grad(
        lambda p, xx: jnp.sum(_block_train(cfg, p, xx, jnp.int32(0), pos)[0] ** 2)
    )

    def segment(p, xs):
        def step(carry, xx):
            g = grad_fn(carry, xx)
            return jax.tree.map(lambda a, b: a - 1e-3 * b, carry, g), jnp.float32(0)

        final, _ = jax.lax.scan(step, p, xs)
        return final

    t = time_jit(segment, block, x, warmup=warmup, repeats=repeats)
    return t.compile_s, t.mean_s / max(rounds, 1)
