"""Measurement-driven profiling & autotuning (paper appendix Alg. 3).

- ``repro.profile.store``: versioned on-disk profile store
  (``REPRO_PROFILE_DIR``), schema migration + corrupt-entry recovery.
- ``repro.profile.harness``: the single timed-execution code path
  (warmup + ``block_until_ready`` repeats + ``cost_analysis`` cross-check).
- ``repro.profile.autotune``: kernel-knob sweep; winners drive
  ``kernels.ops`` / ``EngineCache`` defaults (env vars still override).
- ``repro.profile.bridge``: measured ``ModelProfile`` resolution for the
  planner + online refinement from observed segment wall-clock.
"""

from repro.profile.autotune import (
    TunedDefaults,
    autotune,
    choose_buckets,
    choose_pack,
    clear_tuned_cache,
    tuned_defaults,
)
from repro.profile.bridge import (
    measurement_runs,
    observe_segment,
    profile_from_payload,
    profile_to_payload,
    resolve_profile,
)
from repro.profile.harness import Timing, measure_kernel_variants, measure_model_profile, time_jit
from repro.profile.store import (
    SCHEMA_VERSION,
    ProfileStore,
    backend_fingerprint,
    default_store,
    model_config_hash,
    profile_key,
    reset_default_stores,
)

__all__ = [
    "SCHEMA_VERSION",
    "ProfileStore",
    "Timing",
    "TunedDefaults",
    "autotune",
    "backend_fingerprint",
    "choose_buckets",
    "choose_pack",
    "clear_tuned_cache",
    "default_store",
    "measure_kernel_variants",
    "measure_model_profile",
    "measurement_runs",
    "model_config_hash",
    "observe_segment",
    "profile_from_payload",
    "profile_key",
    "profile_to_payload",
    "reset_default_stores",
    "resolve_profile",
    "time_jit",
    "tuned_defaults",
]
