"""Versioned on-disk profile store.

One JSON file per entry under a root directory (``REPRO_PROFILE_DIR``,
default ``~/.cache/repro/profile``). Entries are keyed by a *kind*
(``"layer_profile"`` / ``"autotune"``) plus a key dict — typically the
backend fingerprint, a ``ModelConfig`` content hash, dtype and the
batch/seq geometry — hashed into the filename, with the full key echoed
into the record so entries stay self-describing.

Robustness contract:
- **Schema versioning.** Every record carries ``schema``; reads migrate
  older versions forward (``_MIGRATIONS``) and persist the upgraded form.
  An unknown *newer* schema is ignored (forward compatibility: an old
  binary never misparses a new record).
- **Corrupt-entry recovery.** Unparseable or structurally invalid files
  are quarantined to ``<name>.corrupt`` and treated as missing — one bad
  write (power loss, concurrent writer on NFS) never poisons the store.
  Writes are atomic (tmp file + ``os.replace``).
- **In-process cache.** Repeat reads of one entry hit a dict, not the
  filesystem; ``put`` refreshes it. The cache is per-``ProfileStore``;
  ``default_store()`` returns a process-wide instance per root dir.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Any, Dict, List, Optional

import jax

SCHEMA_VERSION = 2

_ENV_DIR = "REPRO_PROFILE_DIR"


def default_root() -> str:
    env = os.environ.get(_ENV_DIR, "").strip()
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "profile")


def backend_fingerprint() -> str:
    """Identity of the execution backend a measurement is valid for.

    Includes the Pallas-dispatch mode: interpret-mode timings on CPU say
    nothing about the jnp path and vice versa, so they must never share
    an entry.
    """
    from repro.kernels import ops

    backend = jax.default_backend()
    try:
        kind = jax.devices(backend)[0].device_kind
    except Exception:
        kind = "unknown"
    pallas = 1 if ops._use_pallas() else 0
    return f"{backend}|{kind}|pallas={pallas}|jax={jax.__version__}"


def model_config_hash(cfg: Any) -> str:
    """Content hash of a ``ModelConfig`` (order-independent, by value)."""
    d = dataclasses.asdict(cfg)
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def profile_key(cfg: Any, batch: int, seq: int, backend: Optional[str] = None) -> Dict:
    """The store key for one (backend, model, dtype, geometry) profile."""
    return {
        "backend": backend or backend_fingerprint(),
        "model": model_config_hash(cfg),
        "model_name": cfg.name,
        "dtype": cfg.compute_dtype,
        "batch": int(batch),
        "seq": int(seq),
    }


def _key_id(kind: str, key: Dict) -> str:
    blob = json.dumps({"kind": kind, **key}, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:24]


# ---------------------------------------------------------------------------
# Schema migrations (old version -> next version, chained forward)
# ---------------------------------------------------------------------------


def _migrate_v1(record: Dict) -> Dict:
    """v1 → v2: ``layers`` were bare 5-tuples ``[t_fwd, t_bwd, w, a, a_int]``
    and records carried no provenance; v2 names the fields and defaults
    provenance to ``"measured"`` (v1 stores only held measurements)."""
    payload = record.get("payload", {})
    layers = payload.get("layers")
    if isinstance(layers, list) and layers and isinstance(layers[0], (list, tuple)):
        payload["layers"] = [
            {
                "t_fwd": ly[0], "t_bwd": ly[1], "w_bytes": ly[2],
                "a_bytes": ly[3], "a_internal_bytes": ly[4],
            }
            for ly in layers
        ]
    payload.setdefault("provenance", "measured")
    record["payload"] = payload
    record["schema"] = 2
    return record


_MIGRATIONS = {1: _migrate_v1}


class ProfileStore:
    """Directory of versioned JSON profile/autotune records."""

    def __init__(self, root: Optional[str] = None):
        self.root = root or default_root()
        self._cache: Dict[str, Dict] = {}
        self._lock = threading.RLock()
        self.disk_reads = 0
        self.cache_hits = 0

    # -- paths -------------------------------------------------------------
    def _path(self, kind: str, key: Dict) -> str:
        return os.path.join(self.root, f"{kind}-{_key_id(kind, key)}.json")

    # -- core API ----------------------------------------------------------
    def get(self, kind: str, key: Dict) -> Optional[Dict]:
        """The payload stored under (kind, key), or None.

        Migrates old-schema records forward (persisting the upgrade),
        quarantines corrupt files, ignores records from a newer schema.
        """
        path = self._path(kind, key)
        with self._lock:
            if path in self._cache:
                self.cache_hits += 1
                return self._cache[path]["payload"]
            record = self._load(path)
            if record is None:
                return None
            self._cache[path] = record
            return record["payload"]

    def put(self, kind: str, key: Dict, payload: Dict) -> None:
        """Write (atomically) and refresh the in-process cache."""
        record = {
            "schema": SCHEMA_VERSION,
            "kind": kind,
            "key": key,
            "payload": payload,
        }
        path = self._path(kind, key)
        with self._lock:
            os.makedirs(self.root, exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(record, f, indent=2, default=str)
            os.replace(tmp, path)
            self._cache[path] = record

    def delete(self, kind: str, key: Dict) -> bool:
        path = self._path(kind, key)
        with self._lock:
            self._cache.pop(path, None)
            if os.path.exists(path):
                os.remove(path)
                return True
            return False

    def entries(self) -> List[Dict]:
        """Every readable record in the store (corrupt files skipped)."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for name in sorted(os.listdir(self.root)):
            if not name.endswith(".json"):
                continue
            record = self._load(os.path.join(self.root, name))
            if record is not None:
                out.append(record)
        return out

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()

    # -- internals ---------------------------------------------------------
    def _load(self, path: str) -> Optional[Dict]:
        if not os.path.exists(path):
            return None
        self.disk_reads += 1
        try:
            with open(path) as f:
                record = json.load(f)
            if not isinstance(record, dict) or "payload" not in record:
                raise ValueError("not a profile record")
            schema = int(record.get("schema", 0))
        except (json.JSONDecodeError, ValueError, OSError):
            self._quarantine(path)
            return None
        if schema > SCHEMA_VERSION:
            return None  # written by a newer version: leave it alone
        migrated = False
        while schema < SCHEMA_VERSION:
            fn = _MIGRATIONS.get(schema)
            if fn is None:
                self._quarantine(path)
                return None
            record = fn(record)
            schema = int(record["schema"])
            migrated = True
        if migrated:
            # persist the upgraded form so the migration runs once
            try:
                tmp = path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump(record, f, indent=2, default=str)
                os.replace(tmp, path)
            except OSError:
                pass  # read-only store: serve the migrated record anyway
        return record

    @staticmethod
    def _quarantine(path: str) -> None:
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass


_DEFAULT_STORES: Dict[str, ProfileStore] = {}
_DEFAULT_LOCK = threading.Lock()


def default_store() -> ProfileStore:
    """Process-wide store for the current root (env-sensitive)."""
    root = default_root()
    with _DEFAULT_LOCK:
        store = _DEFAULT_STORES.get(root)
        if store is None:
            store = ProfileStore(root)
            _DEFAULT_STORES[root] = store
        return store


def reset_default_stores() -> None:
    """Drop process-wide store instances (tests switching REPRO_PROFILE_DIR)."""
    with _DEFAULT_LOCK:
        _DEFAULT_STORES.clear()
